"""Cell builder: one (arch x shape x mesh) -> jit-able step + shardings.

A *cell* is the unit of the multi-pod dry-run: the step function
(train / prefill / serve), its abstract inputs (ShapeDtypeStructs — zero
allocation), and explicit in/out shardings derived from the partition rules.
``lower_cell`` is the single entry point used by dryrun.py, the roofline
benchmark and the perf hillclimb.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.registry import ShapeSpec, get_config, SHAPES
from ..models.config import ModelConfig
from ..sharding.partition import (activation_sharding, batch_specs,
                                  cache_specs, dp_axes, named_shardings,
                                  param_specs)
from ..train.optim import AdamWConfig
from ..train.step import make_train_step, make_forward
from .specs import abstract_cache, abstract_train_state, abstract_params
from .specs import input_specs

__all__ = ["CellPlan", "build_cell", "lower_cell", "dp_size"]


@dataclasses.dataclass
class CellPlan:
    """Everything needed to lower one cell."""
    arch: str
    shape: ShapeSpec
    fn: Callable                    # positional-args step function
    abstract_args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    static_argnums: Tuple[int, ...] = ()
    donate_argnums: Tuple[int, ...] = ()


def dp_size(mesh: Mesh) -> int:
    s = 1
    for ax in dp_axes(mesh):
        s *= mesh.shape[ax]
    return s


def _state_shardings(state_abs, mesh: Mesh):
    """TrainState(step, params, opt(mu, nu, count)) -> NamedShardings."""
    p_specs = param_specs(state_abs.params, mesh)
    ns = functools.partial(jax.tree.map,
                           lambda s: NamedSharding(mesh, s))
    rep = NamedSharding(mesh, P())
    return type(state_abs)(
        step=rep,
        params=ns(p_specs),
        opt=type(state_abs.opt)(mu=ns(p_specs), nu=ns(p_specs), count=rep))


def _metric_shardings(mesh: Mesh):
    return None    # let the partitioner pick (scalars -> replicated)


def build_cell(arch: str, shape: ShapeSpec, mesh: Mesh, *,
               q_chunk: int = 512, remat: bool = True,
               microbatch_rows: int = 1,
               extra: Optional[Dict[str, Any]] = None) -> CellPlan:
    """Construct the step fn + abstract args + shardings for one cell.

    ``microbatch_rows`` — per-device batch rows per microbatch for train
    cells (grad-accum count = global_batch / (dp * rows)).
    ``extra`` — hillclimb overrides (e.g. {"remat": False}).
    """
    extra = dict(extra or {})
    q_chunk = extra.pop("q_chunk", q_chunk)
    remat = extra.pop("remat", remat)
    microbatch_rows = extra.pop("microbatch_rows", microbatch_rows)
    loss_chunk = extra.pop("loss_chunk", 0)
    pqkv = extra.pop("pqkv", None)          # PQKVConfig for decode cells

    cfg = get_config(arch)
    batch_abs = input_specs(cfg, shape)
    batch_sh = named_shardings(batch_specs(batch_abs, mesh), mesh)

    if shape.kind == "train":
        state_abs = abstract_train_state(cfg)
        state_sh = _state_shardings(state_abs, mesh)
        dp = dp_size(mesh)
        micro = max(1, shape.global_batch // (dp * microbatch_rows))
        # microbatch sharding constraint: same batch rules on the split batch
        mb_abs = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                (s.shape[0] // micro,) + s.shape[1:], s.dtype), batch_abs)
        mb_constraint = batch_specs(mb_abs, mesh) if micro > 1 else None
        step = make_train_step(cfg, AdamWConfig(), q_chunk=q_chunk,
                               microbatches=micro, remat=remat,
                               mb_constraint=mb_constraint,
                               loss_chunk=loss_chunk)
        return CellPlan(
            arch=arch, shape=shape, fn=step,
            abstract_args=(state_abs, batch_abs),
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,))

    params_abs = abstract_params(cfg)
    if shape.kind == "decode":
        # serving layout: bf16 weights, TP-only (resident on every DP
        # replica — decode must not re-gather 72B params per token step)
        params_abs = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
            if s.dtype == jnp.float32 and len(s.shape) >= 2 else s,
            params_abs)
        params_sh = named_shardings(
            param_specs(params_abs, mesh, fsdp=False), mesh)
    else:
        params_sh = named_shardings(param_specs(params_abs, mesh), mesh)

    if shape.kind == "prefill":
        fwd = make_forward(cfg, q_chunk=q_chunk, remat=remat)

        def prefill_step(params, batch):
            """Last-position logits only — the (B, S, V) tensor never
            materialises; the LM-head matmul runs on (B, 1, d)."""
            h = fwd(params, batch=batch, return_hidden=True)
            from ..models.lm import logits_from_hidden
            return logits_from_hidden(params, cfg, h[:, -1:, :])

        return CellPlan(
            arch=arch, shape=shape, fn=prefill_step,
            abstract_args=(params_abs, batch_abs),
            in_shardings=(params_sh, batch_sh),
            out_shardings=None)

    # decode: serve_step(params, cache, token, pos); with a PQKVConfig in
    # ``extra["pqkv"]`` the cell lowers the PQ-compressed decode instead
    rep = NamedSharding(mesh, P())
    if pqkv is not None:
        from ..serve.pqkv import pq_serve_step
        from .specs import abstract_pq_cache
        cache_abs = abstract_pq_cache(cfg, shape, pqkv)
        cache_sh = named_shardings(cache_specs(cache_abs, mesh), mesh)

        def decode_step(params, cache, token, pos):
            return pq_serve_step(params, cfg, cache, token, pos, pqc=pqkv)
    else:
        cache_abs = abstract_cache(cfg, shape)
        cache_sh = named_shardings(cache_specs(cache_abs, mesh), mesh)
        from ..serve.decode import serve_step

        def decode_step(params, cache, token, pos):
            return serve_step(params, cfg, cache, token, pos)

    tok_sh = named_shardings(batch_specs(
        {"token": batch_abs["token"]}, mesh), mesh)["token"]
    return CellPlan(
        arch=arch, shape=shape, fn=decode_step,
        abstract_args=(params_abs, cache_abs,
                       batch_abs["token"], batch_abs["pos"]),
        in_shardings=(params_sh, cache_sh, tok_sh, rep),
        out_shardings=(None, cache_sh),
        donate_argnums=(1,))


def lower_cell(plan: CellPlan, mesh: Mesh):
    """jit + lower (no compile) under the mesh + activation-spec contexts.

    The activation-sharding context makes ``constrain_batch`` calls inside
    the model pin batch dims to the DP axes during tracing — without it the
    partitioner replicates batches through the layer scans (verified by the
    dry-run cost model; see EXPERIMENTS.md §Perf iteration 1)."""
    jitted = jax.jit(plan.fn,
                     in_shardings=plan.in_shardings,
                     out_shardings=plan.out_shardings,
                     donate_argnums=plan.donate_argnums)
    with mesh, activation_sharding(dp_axes(mesh),
                                   model_size=mesh.shape["model"]):
        return jitted.lower(*plan.abstract_args)
