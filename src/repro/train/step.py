"""Train-state + train_step builders (grad accumulation, mixed precision).

``make_train_step`` returns a pure function suitable for ``jax.jit`` with
in/out shardings; gradient accumulation microbatches via ``lax.scan`` so the
peak activation memory is one microbatch regardless of global batch.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..models import encdec as encdec_mod
from ..models import lm as lm_mod
from ..models.config import ModelConfig
from .losses import next_token_loss
from .optim import AdamWConfig, OptState, adamw_init, adamw_step

__all__ = ["TrainState", "init_train_state", "make_train_step",
           "make_forward"]


class TrainState(NamedTuple):
    step: jnp.ndarray
    params: Any
    opt: OptState


def model_init(cfg: ModelConfig) -> Callable:
    if cfg.family == "encdec":
        return encdec_mod.init_params_encdec
    return lm_mod.init_params


def make_forward(cfg: ModelConfig, q_chunk: int = 512, remat: bool = True):
    if cfg.family == "encdec":
        return functools.partial(encdec_mod.forward_encdec, cfg=cfg,
                                 q_chunk=q_chunk, remat=remat)
    return functools.partial(lm_mod.forward, cfg=cfg, q_chunk=q_chunk,
                             remat=remat)


def init_train_state(key: jax.Array, cfg: ModelConfig) -> TrainState:
    params = model_init(cfg)(key, cfg)
    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      opt=adamw_init(params))


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    q_chunk: int = 512, microbatches: int = 1,
                    remat: bool = True, mb_constraint=None,
                    loss_chunk: int = 0):
    """Build ``train_step(state, batch) -> (state, metrics)``.

    ``batch`` = {"tokens" (B, S), "labels" (B, S), [extras...]}.  With
    ``microbatches > 1`` the batch is split on axis 0 and gradients are
    accumulated with a scan (one microbatch of activations live at a time).

    ``mb_constraint`` (a pytree of PartitionSpec matching one microbatch)
    pins each microbatch's sharding under SPMD so the scan axis is the
    *microbatch* index and the batch axis stays data-sharded — without it,
    the (B,) -> (m, B/m) reshape would leave whole microbatches on single
    devices.  Only used when lowering inside a mesh context.
    """
    fwd = make_forward(cfg, q_chunk=q_chunk, remat=remat)

    def _bf16_cast(params):
        """One bf16 cast of the param tree per microbatch-scan body, OUTSIDE
        the layer scan: FSDP weight all-gathers then structurally move bf16
        (half the bytes of gathering f32 masters), and weight-grad
        cotangents are bf16 at the reduce point (gradient compression); the
        f32 masters only exist sharded.  Norm scales stay f32."""
        return jax.tree.map(
            lambda p: p.astype(jnp.bfloat16)
            if (p.dtype == jnp.float32 and p.ndim >= 2) else p, params)

    def loss_fn(params, mb):
        params = _bf16_cast(params)
        if not loss_chunk:
            logits = fwd(params, batch=mb)
            return next_token_loss(logits, mb["labels"])
        # sequence-chunked loss: the (B, S, V) logits tensor never
        # materialises — each chunk projects + reduces under remat, cutting
        # peak temp by S/loss_chunk at the cost of one extra lm_head
        # forward in the backward pass.
        from ..models.lm import logits_from_hidden
        h = fwd(params, batch=mb, return_hidden=True)
        B, S, _ = h.shape
        n = S // loss_chunk if S % loss_chunk == 0 and S > loss_chunk else 1
        ch = S // n
        hc = h.reshape(B, n, ch, -1).swapaxes(0, 1)          # (n, B, ch, d)
        lc = mb["labels"].reshape(B, n, ch).swapaxes(0, 1)

        @jax.checkpoint
        def chunk_sums(hb, lb):
            logits = logits_from_hidden(params, cfg, hb).astype(jnp.float32)
            lse = jax.nn.logsumexp(logits, axis=-1)
            picked = jnp.take_along_axis(
                logits, jnp.maximum(lb, 0)[..., None], axis=-1)[..., 0]
            mask = (lb != -100).astype(jnp.float32)
            return (jnp.sum((lse - picked) * mask),
                    jnp.sum((lse ** 2) * mask), jnp.sum(mask))

        def body(carry, xs):
            nll, zl, cnt = carry
            a, b, c = chunk_sums(*xs)
            return (nll + a, zl + b, cnt + c), None

        (nll, zl, cnt), _ = jax.lax.scan(
            body, (jnp.float32(0), jnp.float32(0), jnp.float32(0)), (hc, lc))
        denom = jnp.maximum(cnt, 1.0)
        ce = nll / denom
        zloss = zl / denom
        loss = ce + 1e-4 * zloss
        metrics = {"ce": ce, "z_loss": zloss,
                   "ppl": jnp.exp(jnp.clip(ce, 0.0, 20.0)), "tokens": cnt}
        return loss, metrics

    def train_step(state: TrainState, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)
        else:
            def split(x):
                return x.reshape(microbatches, x.shape[0] // microbatches,
                                 *x.shape[1:])
            mbs = jax.tree.map(split, batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)

            def acc(carry, mb):
                g_acc, l_acc = carry
                if mb_constraint is not None:
                    mb = jax.tree.map(
                        lambda x, s: jax.lax.with_sharding_constraint(x, s),
                        mb, mb_constraint)
                (loss, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(state.params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + loss), metrics

            (grads, loss_sum), metrics = jax.lax.scan(
                acc, (zero, jnp.float32(0.0)), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            metrics = jax.tree.map(lambda m: m[-1], metrics)

        params, opt = adamw_step(opt_cfg, state.params, grads, state.opt)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return TrainState(step=state.step + 1, params=params, opt=opt), metrics

    return train_step
