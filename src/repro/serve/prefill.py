"""Batched prefill: one forward pass over the whole prompt that FILLS the
KV cache, returning last-position logits — the production prompt path
(token-sequential `serve_step` prefill is O(S) dispatches and O(S²·L) total
work re-reading the growing cache; this is one chunked-causal pass).

Families: dense / moe / vlm (uniform GQA blocks, incl. gemma2-style
local/global alternation).  SSM/hybrid prefill needs the final recurrent
state and stays on the step path; enc-dec fills its cross-attention cache
via :func:`repro.serve.decode.prefill_cache_encdec`.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.layers import (_qkv, attention, mlp, moe, rms_norm, rotary,
                             mrope_positions, _mrope_tables)
from ..models.lm import LmParams, logits_from_hidden
from ..sharding.partition import constrain_batch

__all__ = ["prefill"]


def _block_prefill(blk, cfg: ModelConfig, h, positions, cos_sin, kc, vc, *,
                   window: int, q_chunk: int):
    """One block over the full prompt; returns (h, k_cache, v_cache)."""
    B, S, _ = h.shape
    h = constrain_batch(h)
    xn = rms_norm(h, blk.ln1, cfg.norm_eps)
    cos, sin = cos_sin
    _, k, v = _qkv(blk.attn, cfg, xn, cos, sin)          # roped k, raw v
    kc = jax.lax.dynamic_update_slice_in_dim(
        kc, k.astype(kc.dtype), 0, axis=1)               # static offset 0
    vc = jax.lax.dynamic_update_slice_in_dim(
        vc, v.astype(vc.dtype), 0, axis=1)
    kv_mask = jnp.ones((B, S), bool)
    a = attention(blk.attn, cfg, xn, positions, causal=True, window=window,
                  q_chunk=q_chunk, cos_sin=cos_sin,
                  kv_override=(k, v, kv_mask))
    if getattr(blk, "post_attn_ln", None) is not None:
        a = rms_norm(a, blk.post_attn_ln, cfg.norm_eps)
    h = h + a
    if cfg.family == "moe" and hasattr(blk, "moe"):
        h = h + moe(blk.moe, cfg, rms_norm(h, blk.ln2, cfg.norm_eps))
    else:
        m = mlp(blk.mlp, rms_norm(h, blk.ln2, cfg.norm_eps), cfg.act)
        if getattr(blk, "post_mlp_ln", None) is not None:
            m = rms_norm(m, blk.post_mlp_ln, cfg.norm_eps)
        h = h + m
    return constrain_batch(h), kc, vc


def prefill(params: LmParams, cfg: ModelConfig, cache: Dict[str, Any],
            batch: Dict[str, jnp.ndarray], *, q_chunk: int = 512
            ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """``batch = {tokens (B, S), [patches]}`` -> (last logits (B, 1, Vp),
    cache with positions [0, S) filled).  ``S`` may be < cache max_len."""
    fam = cfg.family
    assert fam in ("dense", "moe", "vlm"), \
        f"batched prefill: unsupported family {fam}"
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = params.embed[tokens].astype(jnp.bfloat16)
    if cfg.local_global:
        x = x * jnp.bfloat16(cfg.d_model ** 0.5)
    if fam == "vlm" and "patches" in batch:
        proj = jnp.einsum("bpd,de->bpe",
                          batch["patches"].astype(jnp.bfloat16),
                          params.patch_proj.astype(jnp.bfloat16),
                          preferred_element_type=jnp.float32
                          ).astype(jnp.bfloat16)
        x = jax.lax.dynamic_update_slice_in_dim(x, proj, 0, axis=1)
    x = constrain_batch(x)

    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                 (B, S))
    hd = cfg.head_dim_
    if cfg.mrope and "patches" in batch:
        # M-RoPE grid positions apply only to the patch region; text-only
        # requests use plain positions (t=h=w -> identical to 1-D RoPE,
        # matching the decode path)
        mpos = mrope_positions(positions, cfg.n_frontend_tokens,
                               cfg.mrope_sections)
        cos_sin = _mrope_tables(mpos, hd, cfg.rope_theta, cfg.mrope_sections)
    else:
        cos_sin = rotary(positions, hd, cfg.rope_theta)
    q_chunk = min(q_chunk, S)

    if cfg.local_global:
        L = cfg.n_layers
        kc = cache["k"].reshape(L // 2, 2, *cache["k"].shape[1:])
        vc = cache["v"].reshape(L // 2, 2, *cache["v"].shape[1:])

        def body(h, inp):
            blk_pair, kc2, vc2 = inp
            outs = []
            for i, win in enumerate((cfg.sliding_window, 0)):
                blk = jax.tree.map(lambda t: t[i], blk_pair)
                h, k_i, v_i = _block_prefill(
                    blk, cfg, h, positions, cos_sin, kc2[i], vc2[i],
                    window=win, q_chunk=q_chunk)
                outs.append((k_i, v_i))
            return h, (jnp.stack([outs[0][0], outs[1][0]]),
                       jnp.stack([outs[0][1], outs[1][1]]))

        x, (kc, vc) = jax.lax.scan(body, x, (params.blocks, kc, vc))
        new_cache = {"k": kc.reshape(L, *kc.shape[2:]),
                     "v": vc.reshape(L, *vc.shape[2:])}
    else:
        def body(h, inp):
            blk, kc, vc = inp
            h, kc, vc = _block_prefill(blk, cfg, h, positions, cos_sin,
                                       kc, vc, window=0, q_chunk=q_chunk)
            return h, (kc, vc)

        x, (kc, vc) = jax.lax.scan(body, x, (params.blocks, cache["k"],
                                             cache["v"]))
        new_cache = {"k": kc, "v": vc}

    return logits_from_hidden(params, cfg, x[:, -1:, :]), new_cache
